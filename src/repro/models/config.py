"""Architecture configuration for the assigned LM-family transformers.

One :class:`ArchConfig` fully determines a model: layer pattern (attention
variants / RG-LRU / Mamba), FFN kind (dense gated / MoE), embedding and
frontend. ``reduced()`` derives the CPU-smoke-test configuration of the
same family (small widths, few layers/experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "MLAConfig", "ArchConfig", "LAYER_KINDS"]

# layer mixer kinds
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"      # sliding-window attention
MLA = "mla"                    # deepseek multi-head latent attention
RGLRU = "rglru"                # recurrentgemma RG-LRU recurrent block
MAMBA = "mamba"                # mamba-1 selective SSM block

LAYER_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, MLA, RGLRU, MAMBA)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN hidden dim
    n_shared: int = 0          # shared (always-on) experts
    capacity_factor: float = 1.25
    # experts padded up so n_experts % (model TP degree) == 0 (see DESIGN.md)
    first_dense: int = 0       # leading layers with dense FFN instead (deepseek: 1)
    # Beyond-paper perf option (§Perf): dtype of the expert-output combine
    # (the TP psum wire format). bf16 halves the dominant collective.
    combine_dtype: str = "float32"
    # Beyond-paper perf option (§Perf): dispatch tokens to experts within
    # ``dispatch_groups`` batch-aligned groups (set = DP degree) so the
    # gather/scatter and expert tensors shard over dp instead of carrying
    # the GLOBAL token axis through every device (the profile-discovered
    # 16x dispatch blowup). 1 = paper-faithful global dispatch.
    dispatch_groups: int = 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512         # compressed c_kv dim (the MLA KV cache)
    q_lora: int = 1536         # compressed query dim (0 = full-rank q proj)
    rope_dim: int = 64         # decoupled rope key dim (shared across heads)
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    pattern_unit: Tuple[str, ...] = (ATTN_GLOBAL,)
    window: Optional[int] = None           # for attn_local layers
    attn_softcap: Optional[float] = None   # gemma2 attention logit softcap
    final_softcap: Optional[float] = None  # gemma2 final logit softcap
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # SSM / recurrent
    ssm_state: int = 16
    d_conv: int = 4
    expand: int = 2                        # mamba d_inner = expand * d_model
    rglru_width: Optional[int] = None      # defaults to d_model
    # embeddings / frontend
    tied_embeddings: bool = True
    embed_scale: bool = False              # gemma-style sqrt(d) embed scaling
    frontend: Optional[str] = None         # None | "audio_stub" | "vision_stub"
    prefix_len: int = 0                    # vlm: bidirectional prefix tokens
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    # Beyond-paper perf option (EXPERIMENTS.md §Perf): pad the head count
    # up to a TP-shardable multiple with zero-initialized heads (zero wo
    # rows => numerics unchanged) instead of replicating attention.
    pad_heads_to: Optional[int] = None
    # notes recorded for DESIGN.md provenance
    source: str = ""

    @property
    def eff_heads(self) -> int:
        return max(self.pad_heads_to or 0, self.n_heads)

    @property
    def eff_kv_heads(self) -> int:
        # MHA archs pad KV alongside Q so the group stays integral;
        # GQA/MQA kv heads are already <= padded Q and divide it.
        if self.n_kv_heads == self.n_heads:
            return self.eff_heads
        return self.n_kv_heads

    # -- derived -------------------------------------------------------------
    @property
    def pattern(self) -> Tuple[str, ...]:
        """Full per-layer mixer list (len == n_layers)."""
        unit = self.pattern_unit
        reps = self.n_layers // len(unit)
        rem = self.n_layers - reps * len(unit)
        return unit[:rem] + unit * reps  # remainder layers lead (unscanned)

    @property
    def sub_quadratic(self) -> bool:
        """True iff no layer needs a full-length KV cache (long_500k viable)."""
        return all(k in (ATTN_LOCAL, RGLRU, MAMBA) for k in self.pattern_unit)

    @property
    def has_attention(self) -> bool:
        return any(k in (ATTN_GLOBAL, ATTN_LOCAL, MLA) for k in self.pattern_unit)

    @property
    def n_params(self) -> float:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        p = self.vocab * self.d_model * (1 if self.tied_embeddings else 2)
        per_layer = {k: self._mixer_params(k) for k in set(self.pattern)}
        for i, kind in enumerate(self.pattern):
            p += per_layer[kind] + self._ffn_params(i, kind)
        return float(p)

    @property
    def n_active_params(self) -> float:
        """Active params per token (MoE: only routed top-k + shared)."""
        p = self.vocab * self.d_model * (1 if self.tied_embeddings else 2)
        per_layer = {k: self._mixer_params(k) for k in set(self.pattern)}
        for i, kind in enumerate(self.pattern):
            p += per_layer[kind] + self._ffn_params(i, kind, active_only=True)
        return float(p)

    def _mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind == MLA:
            m = self.mla
            q = self.n_heads * (m.nope_head_dim + m.rope_dim)
            p = d * m.q_lora + m.q_lora * q if m.q_lora else d * q
            p += d * (m.kv_lora + m.rope_dim)
            p += m.kv_lora * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d
            return p
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            hd = self.head_dim
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if kind == RGLRU:
            w = self.rglru_width or d
            return 2 * d * w + w * d + 3 * w * self.d_conv + 2 * w * w
        if kind == MAMBA:
            di = self.expand * d
            return 2 * d * di + di * self.d_conv + di * (2 * self.ssm_state + 1) + di + di * d
        raise ValueError(kind)

    def _ffn_params(self, layer_idx: int, kind: str, active_only: bool = False) -> int:
        if kind == MAMBA:
            return 0  # mamba blocks have no separate FFN
        d = self.d_model
        if self.moe is not None and layer_idx >= self.moe.first_dense:
            e = self.moe
            n_eff = (e.top_k if active_only else e.n_experts) + e.n_shared
            return n_eff * 3 * d * e.d_expert + d * e.n_experts  # + router
        return 3 * d * self.d_ff

    def reduced(self) -> "ArchConfig":
        """Same-family smoke configuration runnable on CPU."""
        unit = self.pattern_unit
        n_layers = max(len(unit), 2 if len(unit) == 1 else len(unit))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k),
                d_expert=32, n_shared=min(1, self.moe.n_shared),
                first_dense=min(1, self.moe.first_dense) if self.moe.first_dense else 0,
            )
            n_layers = max(n_layers, 2)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora=16, q_lora=24, rope_dim=8,
                            nope_head_dim=8, v_head_dim=8)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16 if mla is None else 8,
            d_ff=128,
            vocab=256,
            window=min(self.window, 16) if self.window else None,
            moe=moe,
            mla=mla,
            ssm_state=4,
            expand=2,
            rglru_width=64 if self.rglru_width else None,
            prefix_len=min(self.prefix_len, 4),
            dtype="float32",
        )
