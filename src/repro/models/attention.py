"""Attention mixers: GQA (global / sliding-window / softcap / prefix-LM)
and DeepSeek-style MLA (multi-head latent attention with compressed KV).

All tensors follow [B, S, D] activations; attention internal layout is
[B, H, S, hd]. Caches (serving) are functional: ``(k, v)`` or MLA's
``(c_kv, k_rope)`` updated via dynamic_update_slice at ``pos``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..parallel import shard
from .config import ArchConfig
from .layers import apply_rope, dense_init, rope

__all__ = ["init_attn", "apply_attn", "init_mla", "apply_mla"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    h_real, kv_real = cfg.n_heads, cfg.n_kv_heads
    h, hkv = cfg.eff_heads, cfg.eff_kv_heads
    ks = jax.random.split(key, 4)
    wq_r = dense_init(ks[0], (d, h_real, hd), dtype)
    wk_r = dense_init(ks[1], (d, kv_real, hd), dtype)
    wv_r = dense_init(ks[2], (d, kv_real, hd), dtype)
    wo_r = dense_init(ks[3], (h_real * hd, d), dtype)
    if h == h_real:
        return {"wq": wq_r, "wk": wk_r, "wv": wv_r, "wo": wo_r}

    # Head padding (pad_heads_to): real q head (g, r) keeps its kv group —
    # it moves to slot g*group_pad + r; padded slots hold zero queries AND
    # zero wo rows, so numerics are exactly unchanged.
    group = h_real // kv_real
    group_pad = h // hkv
    idx = jnp.asarray(
        [(i // group) * group_pad + (i % group) for i in range(h_real)],
        jnp.int32,
    )
    wq = jnp.zeros((d, h, hd), dtype).at[:, idx].set(wq_r)
    wo = jnp.zeros((h, hd, d), dtype).at[idx].set(
        wo_r.reshape(h_real, hd, d)
    ).reshape(h * hd, d)
    if hkv != kv_real:  # MHA: kv heads pad alongside (group_pad == 1)
        kv_idx = idx
        wk = jnp.zeros((d, hkv, hd), dtype).at[:, kv_idx].set(wk_r)
        wv = jnp.zeros((d, hkv, hd), dtype).at[:, kv_idx].set(wv_r)
    else:
        wk, wv = wk_r, wv_r
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}


def apply_attn(
    p: Dict[str, Any],
    x: jax.Array,                       # [B, S, D]
    cfg: ArchConfig,
    *,
    local: bool,
    positions: jax.Array,               # [S] global positions of x
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # k, v [B, Hkv, Sc, hd]
    pos: Optional[jax.Array] = None,    # scalar write offset into the cache
    prefill: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, s, d = x.shape
    h, hkv, hd = cfg.eff_heads, cfg.eff_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])

    cos, sin = rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "heads")
    k = shard(k, "kv_heads")
    v = shard(v, "kv_heads")

    window = cfg.window if local else None
    new_cache = None
    if cache is not None:
        ck, cv = cache
        ring = window is not None and ck.shape[2] <= window
        if ring:
            # ring-buffer window cache: keep only the trailing buffer rows
            rows = ck.shape[2]
            ck = jnp.concatenate([ck, k], axis=2)[:, :, -rows:]
            cv = jnp.concatenate([cv, v], axis=2)[:, :, -rows:]
        else:
            ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, pos, 0))
        ck = shard(ck, "kv_cache")
        cv = shard(cv, "kv_cache")
        new_cache = (ck, cv)

    if cache is None or prefill:
        # attention within the current segment (training, or prefill where
        # the cache starts empty and all context is in this call)
        out = ops.attention(
            q, k, v, causal=True, window=window, softcap=cfg.attn_softcap,
            prefix_len=cfg.prefix_len,
        )
    else:
        ck, cv = new_cache
        if window is not None and ck.shape[2] <= window:
            q_offset = ck.shape[2] - s       # query at the buffer tail
            min_col = ck.shape[2] - s - pos  # mask unwritten warmup rows
        else:
            q_offset = pos
            min_col = None
        out = _cached_attention(
            q, ck, cv, q_offset=q_offset, window=window,
            softcap=cfg.attn_softcap, prefix_len=cfg.prefix_len,
            min_col=min_col,
        )

    out = shard(out, "heads")
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    y = jnp.einsum("bsk,kd->bsd", out, p["wo"])
    return shard(y, "act_btd"), new_cache


def _cached_attention(q, k, v, *, q_offset, window, softcap, prefix_len,
                      min_col=None):
    """Attention against a cache where ``q_offset`` may be a traced scalar.

    The kernels take static offsets; for decode we mask with the dynamic
    position instead: mask = cols <= q_offset + row_index.
    """
    b, h, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    group = h // hkv
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(b, hkv, group, sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkld->bkgql", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = q_offset + jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = cols <= rows
    if window is not None:
        mask &= cols > rows - window
    if prefix_len:
        mask |= cols < prefix_len
    if min_col is not None:
        mask &= cols >= min_col
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgql,bkld->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank compressed KV; cache is (c_kv, k_rope)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qd = m.nope_head_dim + m.rope_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora, h, qd), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora + m.rope_dim), dtype),
        "wkv_b": dense_init(ks[3], (m.kv_lora, h, m.nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d), dtype),
    }


def apply_mla(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # c_kv [B,Sc,kv_lora], k_rope [B,Sc,rope]
    pos: Optional[jax.Array] = None,
    prefill: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads

    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,rhk->bhsk", q, p["wq_b"])  # [B, H, S, nope+rope]
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope_new = kv_a[..., : m.kv_lora], kv_a[..., m.kv_lora :]

    cos, sin = rope(positions, m.rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope_new = apply_rope(k_rope_new[:, None], cos, sin)[:, 0]  # [B, S, rope]

    new_cache = None
    if cache is not None:
        cc, cr = cache
        cc = jax.lax.dynamic_update_slice(cc, c_kv, (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cr, k_rope_new, (0, pos, 0))
        new_cache = (cc, cr)

    if cache is None or prefill:
        c_all, r_all, q_offset = c_kv, k_rope_new, None  # local segment
    else:
        c_all, r_all = new_cache
        q_offset = pos

    # reconstruct per-head keys/values from the latent representation
    kv = jnp.einsum("bsr,rhk->bhsk", c_all, p["wkv_b"])
    k_nope, v = kv[..., : m.nope_head_dim], kv[..., m.nope_head_dim :]
    k_rope_b = jnp.broadcast_to(r_all[:, None], (b, h) + r_all.shape[1:])
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    q_full = shard(q_full, "heads")
    k_full = shard(k_full, "heads")
    v = shard(v, "heads")

    if q_offset is None:
        out = ops.attention(q_full, k_full, v, causal=True)
    else:
        out = _cached_attention(q_full, k_full, v, q_offset=q_offset,
                                window=None, softcap=None, prefix_len=0)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    y = jnp.einsum("bsk,kd->bsd", out, p["wo"])
    return shard(y, "act_btd"), new_cache
