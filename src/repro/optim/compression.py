"""Gradient compression for cross-pod data parallelism.

At 2 pods x 256 chips, the pod axis crosses the slow inter-pod links; the
standard mitigations are (a) error-feedback int8 quantization (~4x fewer
bytes on the wire) and (b) top-k sparsification. Both are implemented as
pure functions over gradient pytrees so the train loop can apply them
around the pod-axis reduction; the error accumulator makes the compression
unbiased over time (Karimireddy et al. — EF-SGD analysis applies to Adam's
gradient input as used here).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_int8_compress", "ef_int8_decompress", "topk_compress"]


def ef_int8_compress(grads: Any, error: Any) -> Tuple[Any, Any, Any]:
    """Returns (q_int8, scales, new_error). new_error = (g+e) - dequant(q)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, g - deq

    flat, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
        treedef.unflatten([o[2] for o in out]),
    )


def ef_int8_decompress(q: Any, scales: Any) -> Any:
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def topk_compress(grads: Any, frac: float = 0.01) -> Any:
    """Keep the top-|frac| magnitude entries per tensor (zero the rest)."""

    def one(g):
        flat = jnp.abs(g.reshape(-1))
        k = max(int(flat.shape[0] * frac), 1)
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(g) >= thresh, g, 0.0)

    return jax.tree.map(one, grads)
