"""LR schedules: cosine-with-warmup and MiniCPM's Warmup-Stable-Decay
(WSD, arXiv:2404.06395 — the schedule minicpm-2b was trained with)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "wsd_schedule"]


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 floor: float = 0.01):
    """Warmup -> flat plateau -> exponential-ish decay tail (WSD)."""
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        tail = peak_lr * (floor ** t)
        return jnp.where(
            step < warmup, warm, jnp.where(step < warmup + stable, peak_lr, tail)
        )

    return lr
