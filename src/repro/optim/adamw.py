"""AdamW with fp32 master weights (bf16 compute params) and ZeRO-1-style
optimizer-state sharding over the data axes (``opt_specs``)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm", "opt_specs"]


def adamw_init(params: Any) -> Dict[str, Any]:
    # explicit copy: when params are already f32 (smoke configs), astype
    # aliases the same buffer, and donating params+opt together would
    # donate one buffer twice.
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    sq = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / c1, v / c2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * master)
        return new_master, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_master = treedef.flatten_up_to(state["master"])
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(mu, g, m, v) for mu, g, m, v in zip(flat_master, flat_g, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype), new_master, params)
    return new_params, {"step": step, "master": new_master, "m": new_m, "v": new_v}


def opt_specs(
    param_spec_tree: Any, dp: Tuple[str, ...], dp_size: int, shapes: Any
) -> Dict[str, Any]:
    """ZeRO-1: on top of the parameter's own TP sharding, shard master/m/v
    over the data axes along the first unsharded, divisible dimension —
    optimizer state is only needed shard-wise at the update."""

    def zero1(spec: P, shape) -> P:
        dims = tuple(shape.shape)
        if not dims:
            return P()
        entries = list(spec) + [None] * (len(dims) - len(spec))
        for i, d in enumerate(dims):
            if entries[i] is None and dp_size > 0 and d % dp_size == 0:
                entries[i] = dp
                break
        return P(*entries)

    return {
        "step": P(),
        "master": jax.tree.map(zero1, param_spec_tree, shapes),
        "m": jax.tree.map(zero1, param_spec_tree, shapes),
        "v": jax.tree.map(zero1, param_spec_tree, shapes),
    }
