"""Optimizer substrate: AdamW with fp32 master weights, LR schedules
(cosine + MiniCPM's WSD), global-norm clipping, and error-feedback
gradient compression for cross-pod data-parallel reduction."""

from .adamw import adamw_init, adamw_update, clip_by_global_norm, opt_specs
from .compression import ef_int8_compress, ef_int8_decompress, topk_compress
from .schedules import cosine_schedule, wsd_schedule

__all__ = [
    "adamw_init", "adamw_update", "clip_by_global_norm", "opt_specs",
    "cosine_schedule", "wsd_schedule",
    "ef_int8_compress", "ef_int8_decompress", "topk_compress",
]
