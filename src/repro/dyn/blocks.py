"""Small-CNN kernel library for the dynamic/static DNN workloads.

Each kernel is an :class:`AcsKernel` over NCHW tensors (batch 1, small
feature maps — the paper's "<200 CTAs" regime, Fig 8). Weights are
read-only buffers: reads never hazard against reads, so weight sharing
does not serialize independent branches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.buffers import Buffer, BufferPool
from ..core.wrapper import AcsKernel, TaskStream

__all__ = [
    "conv", "dwconv", "pool_avg", "pool_max", "add2", "add3", "concat2",
    "dense", "gap", "mix_weights", "init_conv", "init_dense", "DynParams",
    "launch_conv", "launch_add", "conv_flops",
    "DYN_KERNELS", "register_device_kernels",
]


# -- kernel bodies -----------------------------------------------------------

def _conv_fn(x, w, stride, relu):
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    return jax.nn.relu(out) if relu else out


def _dwconv_fn(x, w, stride, relu):
    c = x.shape[1]
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=c,
    )
    return jax.nn.relu(out) if relu else out


def _pool_fn(x, kind, k, stride):
    if kind == "avg":
        out = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 1, k, k), (1, 1, stride, stride), "SAME"
        ) / float(k * k)
    else:
        out = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, stride, stride), "SAME"
        )
    return out


def _add2_fn(a, b):
    return a + b


def _add3_fn(a, b, c):
    return a + b + c


def _concat2_fn(a, b):
    return jnp.concatenate([a, b], axis=1)


def _dense_fn(x, w):
    return x @ w


def _gap_fn(x):
    return jnp.mean(x, axis=(2, 3))


def _mix_weights_fn(experts, r):
    """CondConv: example-dependent weights = Σ_e σ(r_e) · W_e.
    experts [E, O, I, kh, kw]; r [1, E] -> [O, I, kh, kw]."""
    return jnp.einsum("e,eoihw->oihw", jax.nn.sigmoid(r[0]), experts)


def _upsample2_fn(x):
    """Nearest-neighbour 2x upsample (NCHW)."""
    return jnp.repeat(jnp.repeat(x, 2, axis=2), 2, axis=3)


def conv_flops(inputs, outputs, *static):
    from ..core.task import operand_shape

    xs, ws = operand_shape(inputs[0]), operand_shape(inputs[1])
    os = operand_shape(outputs[0])
    kh, kw = ws[-2], ws[-1]
    cin = ws[1]
    return 2.0 * np.prod(os, dtype=np.float64) * cin * kh * kw


conv = AcsKernel(name="conv", fn=_conv_fn, flops=conv_flops)
dwconv = AcsKernel(name="dwconv", fn=_dwconv_fn, flops=conv_flops)
pool_avg = AcsKernel(name="pool_avg", fn=lambda x, k, s: _pool_fn(x, "avg", k, s))
pool_max = AcsKernel(name="pool_max", fn=lambda x, k, s: _pool_fn(x, "max", k, s))
add2 = AcsKernel(name="add2", fn=_add2_fn)
add3 = AcsKernel(name="add3", fn=_add3_fn)
concat2 = AcsKernel(name="concat2", fn=_concat2_fn)
dense = AcsKernel(name="dense", fn=_dense_fn,
                  flops=lambda i, o, *s: 2.0 * np.prod((i[0].shape[0], i[1].shape[0], i[1].shape[1]), dtype=np.float64))
gap = AcsKernel(name="gap", fn=_gap_fn)
mix_weights = AcsKernel(name="mix_weights", fn=_mix_weights_fn)
upsample2 = AcsKernel(name="upsample2", fn=_upsample2_fn)

#: Every kernel the dyn/static DNN builders can emit — the fixed opcode set
#: the device-resident window (DESIGN §2 A3) needs registered ahead of time.
DYN_KERNELS = (conv, dwconv, pool_avg, pool_max, add2, add3, concat2,
               dense, gap, mix_weights, upsample2)

#: Switch-branch table for the device ready-queue fast path: only the
#: row-shape-preserving elementwise kernels qualify (conv/pool/dense etc.
#: change geometry or carry static args the on-device ``lax.switch``
#: cannot thread). Epochs mixing in any other opcode fall back to the
#: ``lax.while_loop`` interpreter — same single dispatch, no fast path.
SWITCH_BRANCHES = {"add2": _add2_fn, "add3": _add3_fn}


def register_device_kernels(registry) -> Dict[str, int]:
    """Register the CNN kernel set with a
    :class:`~repro.core.DeviceOpRegistry` (fn-less — see
    ``repro.sim.engine.register_device_kernels``). Returns name -> opcode;
    the shape classes each opcode runs over (one per feature-map / weight
    geometry) are recorded at lowering time in ``registry.classes_seen``."""
    for name, fn in SWITCH_BRANCHES.items():
        registry.register_switch_branch(name, fn)
    return {k.name: registry.register(k.name) for k in DYN_KERNELS}


def launch_upsample2(stream: TaskStream, pool: BufferPool, x: Buffer) -> Buffer:
    out = pool.alloc((x.shape[0], x.shape[1], x.shape[2] * 2, x.shape[3] * 2), np.float32)
    upsample2.launch(stream, inputs=(x,), outputs=(out,))
    return out


# -- parameter helpers --------------------------------------------------------

@dataclasses.dataclass
class DynParams:
    """Named weight buffers for one network instance."""

    pool: BufferPool
    weights: Dict[str, Buffer] = dataclasses.field(default_factory=dict)

    def conv_w(self, name: str, cout: int, cin: int, k: int, rng) -> Buffer:
        if name not in self.weights:
            w = (rng.randn(cout, cin, k, k) * np.sqrt(2.0 / (cin * k * k))).astype(np.float32)
            self.weights[name] = self.pool.from_array(jnp.asarray(w), name=name)
        return self.weights[name]

    def dense_w(self, name: str, din: int, dout: int, rng) -> Buffer:
        if name not in self.weights:
            w = (rng.randn(din, dout) / np.sqrt(din)).astype(np.float32)
            self.weights[name] = self.pool.from_array(jnp.asarray(w), name=name)
        return self.weights[name]

    def raw(self, name: str, arr) -> Buffer:
        if name not in self.weights:
            self.weights[name] = self.pool.from_array(jnp.asarray(arr), name=name)
        return self.weights[name]


def init_conv(rng, cout, cin, k):
    return (rng.randn(cout, cin, k, k) * np.sqrt(2.0 / (cin * k * k))).astype(np.float32)


def init_dense(rng, din, dout):
    return (rng.randn(din, dout) / np.sqrt(din)).astype(np.float32)


# -- launch helpers ------------------------------------------------------------

def launch_conv(stream: TaskStream, pool: BufferPool, x: Buffer, w: Buffer,
                *, stride: int = 1, relu: bool = True, depthwise: bool = False) -> Buffer:
    cout = w.shape[0] if not depthwise else x.shape[1]
    h = -(-x.shape[2] // stride)
    wd = -(-x.shape[3] // stride)
    out = pool.alloc((x.shape[0], cout, h, wd), np.float32)
    kern = dwconv if depthwise else conv
    kern.launch(stream, inputs=(x, w), outputs=(out,), static_args=(stride, relu))
    return out


def launch_pool(stream: TaskStream, pool: BufferPool, x: Buffer, *, kind: str = "avg",
                k: int = 3, stride: int = 1) -> Buffer:
    h = -(-x.shape[2] // stride)
    w = -(-x.shape[3] // stride)
    out = pool.alloc((x.shape[0], x.shape[1], h, w), np.float32)
    (pool_avg if kind == "avg" else pool_max).launch(
        stream, inputs=(x,), outputs=(out,), static_args=(k, stride)
    )
    return out


def launch_add(stream: TaskStream, pool: BufferPool, xs) -> Buffer:
    xs = list(xs)
    if len(xs) == 1:
        return xs[0]
    acc = xs[0]
    i = 1
    while i < len(xs):
        take = xs[i : i + 2]
        out = pool.alloc(tuple(acc.shape), np.float32)
        if len(take) == 2:
            add3.launch(stream, inputs=(acc, take[0], take[1]), outputs=(out,))
            i += 2
        else:
            add2.launch(stream, inputs=(acc, take[0]), outputs=(out,))
            i += 1
        acc = out
    return acc


def launch_concat(stream: TaskStream, pool: BufferPool, a: Buffer, b: Buffer) -> Buffer:
    out = pool.alloc((a.shape[0], a.shape[1] + b.shape[1], a.shape[2], a.shape[3]), np.float32)
    concat2.launch(stream, inputs=(a, b), outputs=(out,))
    return out


def launch_classifier(stream: TaskStream, pool: BufferPool, x: Buffer, params: DynParams,
                      n_classes: int, rng) -> Buffer:
    pooled = pool.alloc((x.shape[0], x.shape[1]), np.float32)
    gap.launch(stream, inputs=(x,), outputs=(pooled,))
    w = params.dense_w("classifier", x.shape[1], n_classes, rng)
    logits = pool.alloc((x.shape[0], n_classes), np.float32)
    dense.launch(stream, inputs=(pooled, w), outputs=(logits,))
    return logits
