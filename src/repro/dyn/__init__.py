"""Dynamic & static DNN workloads — the paper's workloads 2 and 3 (§II-C, §V).

* ``instanas``       — InstaNAS-like instance-aware dynamic CNN: a per-input
                       controller picks a subset of candidate blocks per stage.
* ``dynamic_routing``— grid-of-cells segmentation net with per-input gates.
* ``condconv``       — CondConv mixture-of-experts CNN: example-dependent
                       convolution weights mixed at runtime.
* ``static_nets``    — NAS-produced irregular static CNNs: NASNet-like,
                       AmoebaNet-like, SqueezeNet, RandomWire.

Every network is expressed as a stream of small ACS kernels over a
``BufferPool`` — batch size 1 (paper §V), small feature maps, so the GPU/TPU
would be underutilized by serial execution.
"""

from .blocks import DynParams, init_conv, init_dense
from .condconv import build_condconv, init_condconv
from .dynamic_routing import build_dynamic_routing, init_dynamic_routing
from .instanas import build_instanas, init_instanas
from .static_nets import (
    build_amoebanet,
    build_nasnet,
    build_randwire,
    build_squeezenet,
    init_amoebanet,
    init_nasnet,
    init_randwire,
    init_squeezenet,
)

WORKLOADS = {
    "instanas": (init_instanas, build_instanas, True),
    "dynamic_routing": (init_dynamic_routing, build_dynamic_routing, True),
    "condconv": (init_condconv, build_condconv, True),
    "nasnet": (init_nasnet, build_nasnet, False),
    "amoebanet": (init_amoebanet, build_amoebanet, False),
    "squeezenet": (init_squeezenet, build_squeezenet, False),
    "randwire": (init_randwire, build_randwire, False),
}

__all__ = ["WORKLOADS", "DynParams"] + [n for n in dir() if n.startswith(("build_", "init_"))]
