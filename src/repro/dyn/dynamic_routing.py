"""Dynamic Routing-like segmentation net (paper §V: "Dynamic-A 16 layer").

A grid of cells (layers x scales). Each cell is a small conv; per-input
soft gates decide which inter-cell paths (same-scale / down / up) are
active, so the routed sub-graph — and hence the kernel stream — varies per
image (Fig 6b's multi-path structure).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..core.buffers import Buffer, BufferPool
from ..core.wrapper import TaskStream
from .blocks import DynParams, launch_add, launch_conv, launch_upsample2

N_LAYERS = 4
N_SCALES = 3
CH = 12
IMG = 32
N_CLASSES = 8


def init_dynamic_routing(seed: int = 0) -> DynParams:
    rng = np.random.RandomState(seed)
    params = DynParams(BufferPool())
    params.conv_w("stem", CH, 3, 3, rng)
    for l in range(N_LAYERS):
        for s in range(N_SCALES):
            params.conv_w(f"cell{l}_{s}", CH, CH, 3, rng)
            params.conv_w(f"down{l}_{s}", CH, CH, 1, rng)  # stride-2 path
            params.conv_w(f"up{l}_{s}", CH, CH, 1, rng)    # post-upsample 1x1
    params.conv_w("head", N_CLASSES, CH, 1, rng)
    params._rng = rng
    return params


def gates(x_value: np.ndarray) -> Dict[Tuple[int, int, str], bool]:
    """Per-(layer, scale, direction) path gate from input statistics."""
    x = np.asarray(x_value)
    stat = float(np.tanh(np.mean(x)) + np.std(x) % 1.0)
    g = {}
    d_code = {"same": 0, "down": 1, "up": 2}
    for l in range(N_LAYERS):
        for s in range(N_SCALES):
            for d in ("same", "down", "up"):
                # stable arithmetic hash (python's str hash is per-process
                # salted, which would make the gates nondeterministic)
                v = (((l * 31 + s) * 31 + d_code[d]) * 2654435761 % 101) / 101.0
                g[(l, s, d)] = (v + stat) % 1.0 > 0.4
            # ensure at least one VALID outgoing path per cell ("down" needs a
            # coarser scale to exist, "up" a finer one)
            valid_open = g[(l, s, "same")] or (
                g[(l, s, "down")] and s + 1 < N_SCALES
            ) or (g[(l, s, "up")] and s - 1 >= 0)
            if not valid_open:
                g[(l, s, "same")] = True
    return g


def build_dynamic_routing(params: DynParams, stream: TaskStream, x_value) -> Buffer:
    pool = params.pool
    x = pool.from_array(x_value)  # [1, 3, 32, 32]
    stem = launch_conv(stream, pool, x, params.weights["stem"], stride=2)  # 16x16

    # grid[l][s] = activation at layer l, scale s (scale 0 finest: 16x16)
    grid: Dict[int, Buffer] = {0: stem}
    g = gates(np.asarray(x_value))

    for l in range(N_LAYERS):
        nxt: Dict[int, Buffer] = {}
        contrib: Dict[int, list] = {s: [] for s in range(N_SCALES)}
        for s, h in grid.items():
            # same-scale path
            if g[(l, s, "same")]:
                contrib[s].append(launch_conv(stream, pool, h, params.weights[f"cell{l}_{s}"]))
            # downsample path (to coarser scale s+1)
            if s + 1 < N_SCALES and g[(l, s, "down")]:
                d = launch_conv(stream, pool, h, params.weights[f"down{l}_{s}"], stride=2)
                contrib[s + 1].append(d)
            # upsample path (to finer scale s-1)
            if s - 1 >= 0 and g[(l, s, "up")]:
                u = launch_upsample2(stream, pool, h)
                u = launch_conv(stream, pool, u, params.weights[f"up{l}_{s}"])
                contrib[s - 1].append(u)
        for s, outs in contrib.items():
            if outs:
                nxt[s] = launch_add(stream, pool, outs)
        grid = nxt or grid

    # head: merge everything to the finest surviving scale
    finest = min(grid)
    h = grid[finest]
    for s in sorted(grid):
        if s == finest:
            continue
        u = grid[s]
        for _ in range(s - finest):
            u = launch_upsample2(stream, pool, u)
        hsum = launch_add(stream, pool, [h, u])
        h = hsum
    return launch_conv(stream, pool, h, params.weights["head"], relu=False)


def random_input(rng: np.random.RandomState):
    return rng.randn(1, 3, IMG, IMG).astype(np.float32)
