"""CondConv-like mixture-of-experts CNN (paper §V: 4 experts, efficientnet
backbone). Convolution weights are computed *at runtime* per example:
w(x) = Σ_e σ(r_e(x)) · W_e. The weight-mixing kernels and the convs that
consume them form runtime RAW dependencies that ACS tracks through the
segment checks — and the router/mix/conv kernels of different blocks are
independent, giving ACS concurrency to harvest.
"""

from __future__ import annotations

import numpy as np

from ..core.buffers import Buffer, BufferPool
from ..core.wrapper import TaskStream
from .blocks import (
    DynParams,
    dense,
    gap,
    launch_classifier,
    launch_conv,
    mix_weights,
)

N_EXPERTS = 4
N_BLOCKS = 4
CH = 16
IMG = 32
N_CLASSES = 10


def init_condconv(seed: int = 0) -> DynParams:
    rng = np.random.RandomState(seed)
    params = DynParams(BufferPool())
    params.conv_w("stem", CH, 3, 3, rng)
    for b in range(N_BLOCKS):
        cin = CH
        # expert bank for the block's 3x3 conv: [E, O, I, 3, 3]
        bank = (rng.randn(N_EXPERTS, cin, cin, 3, 3) * np.sqrt(2.0 / (cin * 9))).astype(
            np.float32
        )
        params.raw(f"b{b}_bank", bank)
        params.dense_w(f"b{b}_router", cin, N_EXPERTS, rng)
        params.conv_w(f"b{b}_pw", cin, cin, 1, rng)
    params._rng = rng
    return params


def build_condconv(params: DynParams, stream: TaskStream, x_value) -> Buffer:
    pool = params.pool
    rng = params._rng
    x = pool.from_array(x_value)
    h = launch_conv(stream, pool, x, params.weights["stem"], stride=2)

    for b in range(N_BLOCKS):
        cin = h.shape[1]
        # router: gap -> dense -> routing logits (value-level input dependence)
        feat = pool.alloc((1, cin), np.float32)
        gap.launch(stream, inputs=(h,), outputs=(feat,))
        r = pool.alloc((1, N_EXPERTS), np.float32)
        dense.launch(stream, inputs=(feat, params.weights[f"b{b}_router"]), outputs=(r,))
        # mix expert weights for THIS example (runtime weight buffer)
        mixed = pool.alloc((cin, cin, 3, 3), np.float32)
        mix_weights.launch(
            stream, inputs=(params.weights[f"b{b}_bank"], r), outputs=(mixed,)
        )
        # conv with the example-dependent weights + residual pointwise conv
        hc = launch_conv(stream, pool, h, mixed)
        h = launch_conv(stream, pool, hc, params.weights[f"b{b}_pw"])
    return launch_classifier(stream, pool, h, params, N_CLASSES, rng)


def random_input(rng: np.random.RandomState):
    return rng.randn(1, 3, IMG, IMG).astype(np.float32)
