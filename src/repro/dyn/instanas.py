"""InstaNAS-like instance-aware dynamic CNN (paper §II-C, Fig 6b; I-NAS in §V).

A controller inspects the input and, per stage, activates a subset of
candidate blocks; active block outputs are summed. The computational graph
therefore differs per image — the defining property ACS targets. The
controller here is a cheap deterministic function of input statistics
(regional means), standing in for InstaNAS's learned policy: what matters
for the systems evaluation is that the kernel stream is input-dependent.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from ..core.buffers import Buffer, BufferPool
from ..core.wrapper import TaskStream
from .blocks import DynParams, launch_add, launch_classifier, launch_conv

N_STAGES = 4
N_CANDIDATES = 4
CHANNELS = 16
IMG = 32
N_CLASSES = 10


def init_instanas(seed: int = 0) -> DynParams:
    rng = np.random.RandomState(seed)
    params = DynParams(BufferPool())
    params.conv_w("stem", CHANNELS, 3, 3, rng)
    for s in range(N_STAGES):
        cin = CHANNELS * (2 ** min(s, 2))
        cout = cin
        # candidates: conv3x3, conv5x5, conv1x1, dw3x3+pw1x1
        params.conv_w(f"s{s}_c0", cout, cin, 3, rng)
        params.conv_w(f"s{s}_c1", cout, cin, 5, rng)
        params.conv_w(f"s{s}_c2", cout, cin, 1, rng)
        params.conv_w(f"s{s}_c3dw", cin, 1, 3, rng)
        params.conv_w(f"s{s}_c3pw", cout, cin, 1, rng)
        if s < N_STAGES - 1:
            nxt = CHANNELS * (2 ** min(s + 1, 2))
            params.conv_w(f"s{s}_down", nxt, cout, 3, rng)
    params._rng = rng  # classifier lazily initialized
    return params


def controller(x_value: np.ndarray) -> List[List[bool]]:
    """Per-stage candidate mask from input statistics (≥1 block active)."""
    x = np.asarray(x_value)
    qs = [float(np.mean(x[..., i::4, j::4])) for i in range(2) for j in range(2)]
    masks = []
    for s in range(N_STAGES):
        m = [((abs(hash((s, k))) % 7) / 7.0 + qs[k % 4]) % 1.0 > 0.45 for k in range(N_CANDIDATES)]
        if not any(m):
            m[s % N_CANDIDATES] = True
        masks.append(m)
    return masks


def build_instanas(params: DynParams, stream: TaskStream, x_value) -> Buffer:
    pool = params.pool
    rng = params._rng
    x = pool.from_array(x_value)  # [1, 3, 32, 32]
    h = launch_conv(stream, pool, x, params.weights["stem"], stride=2)  # 16x16
    masks = controller(np.asarray(x_value))
    for s in range(N_STAGES):
        outs = []
        if masks[s][0]:
            outs.append(launch_conv(stream, pool, h, params.weights[f"s{s}_c0"]))
        if masks[s][1]:
            outs.append(launch_conv(stream, pool, h, params.weights[f"s{s}_c1"]))
        if masks[s][2]:
            outs.append(launch_conv(stream, pool, h, params.weights[f"s{s}_c2"]))
        if masks[s][3]:
            d = launch_conv(stream, pool, h, params.weights[f"s{s}_c3dw"], depthwise=True)
            outs.append(launch_conv(stream, pool, d, params.weights[f"s{s}_c3pw"]))
        h = launch_add(stream, pool, outs)
        if s < N_STAGES - 1:
            h = launch_conv(stream, pool, h, params.weights[f"s{s}_down"], stride=2)
    return launch_classifier(stream, pool, h, params, N_CLASSES, rng)


def random_input(rng: np.random.RandomState):
    return rng.randn(1, 3, IMG, IMG).astype(np.float32)
