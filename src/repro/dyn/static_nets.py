"""Static NAS-produced CNNs with irregular graphs (paper §V / §VI-C):
NASNet-like, AmoebaNet-like, SqueezeNet, RandomWire. Their graphs are fixed
across inputs (so DAG frameworks amortize construction — Fig 27), but the
many small parallel branches still underutilize a serial stream.
"""

from __future__ import annotations

from typing import List

import numpy as np

from ..core.buffers import Buffer, BufferPool
from ..core.wrapper import TaskStream
from .blocks import (
    DynParams,
    launch_add,
    launch_classifier,
    launch_concat,
    launch_conv,
    launch_pool,
)

IMG = 32
N_CLASSES = 10
CH = 16


# -- NASNet / AmoebaNet style cells -------------------------------------------
# A cell combines two inputs (h_prev, h) through 5 pairwise ops; op identities
# are fixed per architecture seed (NASNet seed=11, Amoeba seed=23) — standing
# in for the published cell genotypes' irregular branch structure.

_OP_NAMES = ("conv3", "conv5", "conv1", "pool_avg", "pool_max", "identity")


def _init_cellnet(seed: int, arch_seed: int, n_cells: int) -> DynParams:
    rng = np.random.RandomState(seed)
    arch = np.random.RandomState(arch_seed)
    params = DynParams(BufferPool())
    params.conv_w("stem", CH, 3, 3, rng)
    genotype = []
    for c in range(n_cells):
        combos = []
        for k in range(5):
            op_a = _OP_NAMES[arch.randint(len(_OP_NAMES))]
            op_b = _OP_NAMES[arch.randint(len(_OP_NAMES))]
            src_a = arch.randint(2 + k)  # 0=h_prev, 1=h, 2+. = earlier combos
            src_b = arch.randint(2 + k)
            combos.append((op_a, src_a, op_b, src_b))
        genotype.append(combos)
        for k, (op_a, _, op_b, _) in enumerate(combos):
            for tag, op in (("a", op_a), ("b", op_b)):
                if op == "conv3":
                    params.conv_w(f"c{c}_k{k}{tag}", CH, CH, 3, rng)
                elif op == "conv5":
                    params.conv_w(f"c{c}_k{k}{tag}", CH, CH, 5, rng)
                elif op == "conv1":
                    params.conv_w(f"c{c}_k{k}{tag}", CH, CH, 1, rng)
        params.conv_w(f"c{c}_squeeze", CH, 5 * CH, 1, rng)
    params._genotype = genotype
    params._rng = rng
    return params


def _apply_op(stream, pool, params, name, op, x):
    if op in ("conv3", "conv5", "conv1"):
        return launch_conv(stream, pool, x, params.weights[name])
    if op == "pool_avg":
        return launch_pool(stream, pool, x, kind="avg")
    if op == "pool_max":
        return launch_pool(stream, pool, x, kind="max")
    return x  # identity


def _build_cellnet(params: DynParams, stream: TaskStream, x_value) -> Buffer:
    pool = params.pool
    x = pool.from_array(x_value)
    h = launch_conv(stream, pool, x, params.weights["stem"], stride=2)
    h_prev = h
    for c, combos in enumerate(params._genotype):
        states: List[Buffer] = [h_prev, h]
        outs = []
        for k, (op_a, src_a, op_b, src_b) in enumerate(combos):
            a = _apply_op(stream, pool, params, f"c{c}_k{k}a", op_a, states[src_a])
            b = _apply_op(stream, pool, params, f"c{c}_k{k}b", op_b, states[src_b])
            s = launch_add(stream, pool, [a, b])
            states.append(s)
            outs.append(s)
        cat = outs[0]
        for o in outs[1:]:
            cat = launch_concat(stream, pool, cat, o)
        h_prev, h = h, launch_conv(stream, pool, cat, params.weights[f"c{c}_squeeze"])
    return launch_classifier(stream, pool, h, params, N_CLASSES, params._rng)


def init_nasnet(seed: int = 0) -> DynParams:
    return _init_cellnet(seed, arch_seed=11, n_cells=3)


def build_nasnet(params, stream, x_value):
    return _build_cellnet(params, stream, x_value)


def init_amoebanet(seed: int = 0) -> DynParams:
    return _init_cellnet(seed, arch_seed=23, n_cells=3)


def build_amoebanet(params, stream, x_value):
    return _build_cellnet(params, stream, x_value)


# -- SqueezeNet ----------------------------------------------------------------

_FIRE = 4


def init_squeezenet(seed: int = 0) -> DynParams:
    rng = np.random.RandomState(seed)
    params = DynParams(BufferPool())
    params.conv_w("stem", CH, 3, 3, rng)
    c = CH
    for f in range(_FIRE):
        sq = max(c // 4, 4)
        params.conv_w(f"f{f}_squeeze", sq, c, 1, rng)
        params.conv_w(f"f{f}_e1", c // 2, sq, 1, rng)
        params.conv_w(f"f{f}_e3", c // 2, sq, 3, rng)
    params._rng = rng
    return params


def build_squeezenet(params: DynParams, stream: TaskStream, x_value) -> Buffer:
    pool = params.pool
    x = pool.from_array(x_value)
    h = launch_conv(stream, pool, x, params.weights["stem"], stride=2)
    for f in range(_FIRE):
        sq = launch_conv(stream, pool, h, params.weights[f"f{f}_squeeze"])
        e1 = launch_conv(stream, pool, sq, params.weights[f"f{f}_e1"])  # parallel
        e3 = launch_conv(stream, pool, sq, params.weights[f"f{f}_e3"])  # branches
        h = launch_concat(stream, pool, e1, e3)
        if f == 1:
            h = launch_pool(stream, pool, h, kind="max", stride=2)
    return launch_classifier(stream, pool, h, params, N_CLASSES, params._rng)


# -- RandomWire ----------------------------------------------------------------

_N_NODES = 14


def init_randwire(seed: int = 0) -> DynParams:
    rng = np.random.RandomState(seed)
    arch = np.random.RandomState(97)
    params = DynParams(BufferPool())
    params.conv_w("stem", CH, 3, 3, rng)
    # Watts-Strogatz-like DAG over _N_NODES nodes: ring + random rewires,
    # edges directed low->high index (acyclic).
    edges = set()
    for i in range(1, _N_NODES):
        edges.add((i - 1, i))
        if i >= 2 and arch.rand() < 0.6:
            edges.add((arch.randint(max(1, i - 4), i), i))
        if arch.rand() < 0.3:
            edges.add((arch.randint(0, i), i))
    params._edges = sorted(edges)
    for n in range(_N_NODES):
        params.conv_w(f"node{n}", CH, CH, 3, rng)
    params._rng = rng
    return params


def build_randwire(params: DynParams, stream: TaskStream, x_value) -> Buffer:
    pool = params.pool
    x = pool.from_array(x_value)
    stem = launch_conv(stream, pool, x, params.weights["stem"], stride=2)
    acts = {0: launch_conv(stream, pool, stem, params.weights["node0"])}
    in_edges = {n: [a for a, b in params._edges if b == n] for n in range(_N_NODES)}
    for n in range(1, _N_NODES):
        srcs = [acts[a] for a in in_edges[n] if a in acts] or [stem]
        agg = launch_add(stream, pool, srcs)
        acts[n] = launch_conv(stream, pool, agg, params.weights[f"node{n}"])
    sinks = [acts[n] for n in range(_N_NODES) if not any(a == n for a, _ in params._edges)]
    out = launch_add(stream, pool, sinks if sinks else [acts[_N_NODES - 1]])
    return launch_classifier(stream, pool, out, params, N_CLASSES, params._rng)
